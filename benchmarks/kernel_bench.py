"""Exposed-library-kernel benefit (paper §III "Exposing parallel
linear-algebra routines"): per-op wall time, opaque (sealed library call,
epilogue outside) vs tapir (exposed kernel, epilogue fused), on this CPU.

Also times each Pallas kernel in interpret mode vs its jnp oracle for a
correctness-perf sanity line (interpret mode is NOT a TPU perf proxy; the
TPU-side perf evidence is the dry-run roofline — see benchmarks/roofline).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tapir
from repro.core.passes import run_pipeline
from repro.core.schedule import CPU_COST_MODEL
from repro.core.tapir import TapirConfig, cache_stats, clear_cache, use
from repro.models import layers as L


def _t(fn, *a, iters=10):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


INNER = 8   # op applications per timed call (see bench_op docstring)


def bench_op(name, fn, args, iters=10, n_act=1):
    """Times the op *in context*: a ``lax.scan`` of INNER steps in which
    the first ``n_act`` args (the activations) carry a per-iteration
    dependency while the remaining args (the weights) are loop-invariant —
    the way library ops appear in real networks (a time/layer loop).
    Fairness cuts both ways: weight-side fusion setup (concat/stack) is
    hoistable in both modes, and no mode may "win" by hoisting an
    activation projection that a real network recomputes every step.
    (The paper's §III point is exactly that calling context determines
    what the compiler can optimize.)"""
    rows = []
    for mode in ("opaque", "tapir"):
        clear_cache()
        cfg = TapirConfig(mode=mode)

        @jax.jit
        def run(*a):
            with use(cfg):
                acts, weights = a[:n_act], a[n_act:]

                def body(eps, _):
                    # nonlinear full-tensor perturbation: a scalar (or
                    # even multiplicative) carry commutes with linear ops
                    # and XLA hoists the whole GEMM out of the loop; tanh
                    # doesn't distribute, so each iteration really runs
                    cur = tuple(jnp.tanh(x + eps.astype(x.dtype))
                                for x in acts)
                    out = fn(*cur, *weights)
                    outs = out if isinstance(out, (tuple, list)) else (out,)
                    # consume EVERY output: otherwise DCE removes the
                    # unfused ops the fused form still has to compute
                    lead = sum(o.reshape(-1)[0] + o.reshape(-1)[-1]
                               for o in outs)
                    return 1e-30 * lead, lead

                _, ys = jax.lax.scan(body, jnp.zeros((), acts[0].dtype),
                                     None, length=INNER)
                return ys

        t = _t(run, *args, iters=iters) / INNER
        rows.append({"op": name, "mode": mode, "t_s": t})
    ratio = rows[0]["t_s"] / rows[1]["t_s"]
    print(f"{name:24s} opaque={rows[0]['t_s']*1e3:9.3f}ms "
          f"tapir={rows[1]['t_s']*1e3:9.3f}ms ratio={ratio:5.2f}")
    return rows, ratio


# ---------------------------------------------------------------------------
# region_vs_per_op: whole-region capture vs per-op graphs (ISSUE 1 tentpole)
# ---------------------------------------------------------------------------

_RB, _RS, _RD, _RH, _RHKV, _RHD, _RFF = 8, 128, 256, 8, 4, 32, 1024


def _region_block_params(key, n_blocks=4):
    def init(k, shape):
        return jax.random.normal(k, shape, jnp.float32) / np.sqrt(shape[0])
    out = []
    for i in range(n_blocks):
        ks = jax.random.split(jax.random.fold_in(key, i), 7)
        out.append({
            "ln1": jnp.ones((_RD,)), "ln2": jnp.ones((_RD,)),
            "wq": init(ks[0], (_RD, _RH * _RHD)),
            "wk": init(ks[1], (_RD, _RHKV * _RHD)),
            "wv": init(ks[2], (_RD, _RHKV * _RHD)),
            "wo": init(ks[3], (_RH * _RHD, _RD)),
            "wg": init(ks[4], (_RD, _RFF)),
            "wu": init(ks[5], (_RD, _RFF)),
            "wd": init(ks[6], (_RFF, _RD)),
        })
    return out


def _region_block(p, x, cos, sin):
    B, S, _ = x.shape
    xn = L.rmsnorm(x, p["ln1"])
    q = tapir.linear(xn, p["wq"]).reshape(B, S, _RH, _RHD)
    k = tapir.linear(xn, p["wk"]).reshape(B, S, _RHKV, _RHD)
    v = tapir.linear(xn, p["wv"]).reshape(B, S, _RHKV, _RHD)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    a = tapir.attention(q, k, v, causal=True).reshape(B, S, _RH * _RHD)
    x = x + tapir.linear(a, p["wo"])
    xn2 = L.rmsnorm(x, p["ln2"])
    return x + tapir.gated_mlp(xn2, p["wg"], p["wu"], p["wd"])


def _region_forward(params, x, cos, sin, regions: bool):
    for p in params:
        if regions:
            x = tapir.parallel_region(_region_block, name="bench_block")(
                p, x, cos, sin)
        else:
            x = _region_block(p, x, cos, sin)
    return x


def bench_region_vs_per_op(iters: int = 20, json_path="BENCH_region.json"):
    """Times a 4-block transformer forward, per-op graphs vs one region
    graph per block — the framework-overhead + cross-op-fusion regime the
    region tracer targets (no outer jit: this is library-call usage).
    Also times the pass pipeline alone on a 512+-node merged graph."""
    key = jax.random.PRNGKey(0)
    params = _region_block_params(key, 4)
    x = jax.random.normal(jax.random.fold_in(key, 99), (_RB, _RS, _RD))
    cos, sin = L.rope_table(jnp.arange(_RS), _RHD)

    results = {}
    for label, regions in (("per_op", False), ("region", True)):
        clear_cache()
        with use(TapirConfig(mode="tapir", regions=regions)):
            t = _t(lambda *a: _region_forward(params, x, cos, sin, regions),
                   iters=iters)
            results[label] = {"wall_s": t, "cache": cache_stats()}
        print(f"region_vs_per_op {label:8s} {t*1e3:9.3f} ms/fwd "
              f"(pipeline {results[label]['cache']['pipeline_s']*1e3:.1f} ms,"
              f" {results[label]['cache']['size']} cached graphs)")
    speedup = results["per_op"]["wall_s"] / results["region"]["wall_s"]
    print(f"region_vs_per_op speedup: {speedup:.2f}x")

    # pass-pipeline wall time on a big merged graph (the complexity fix:
    # worklist epilogue fusion + consumer-indexed replace_uses)
    big_params = _region_block_params(jax.random.fold_in(key, 7), 32)
    with use(TapirConfig(mode="tapir")):
        g = tapir.capture_region(
            lambda x: _region_forward(big_params, x, cos, sin, False), x)
        n_nodes = len(g.nodes)
        t0 = time.perf_counter()
        run_pipeline(g, "tapir", CPU_COST_MODEL, "cpu")
        pipe_s = time.perf_counter() - t0
    print(f"pipeline on {n_nodes}-node region graph: {pipe_s*1e3:.1f} ms")

    out = {"per_op": results["per_op"], "region": results["region"],
           "speedup": speedup,
           "pipeline_nodes": n_nodes, "pipeline_s": pipe_s,
           "config": {"blocks": 4, "B": _RB, "S": _RS, "d": _RD}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# decode_region_vs_per_op: stateful decode regions (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------

_DMAX = 128   # KV-cache capacity for the decode bench


def _decode_block(p, x, ck, cv, pos, cos, sin):
    """One transformer decode step against a KV cache slab, written with
    the public stateful ops: under region capture the cache writes become
    donated dynamic_update_slice nodes and the whole block is one jit."""
    from repro.models.transformer import _decode_attention
    B = x.shape[0]
    xn = L.rmsnorm(x, p["ln1"])
    q, k, v = tapir.multi_linear(xn, [p["wq"], p["wk"], p["wv"]])
    q = q.reshape(B, 1, _RH, _RHD)
    k = k.reshape(B, 1, _RHKV, _RHD)
    v = v.reshape(B, 1, _RHKV, _RHD)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    ck = tapir.cache_write(ck, k, (0, pos, 0, 0))
    cv = tapir.cache_write(cv, v, (0, pos, 0, 0))
    o = _decode_attention(q, ck, cv, pos + 1)
    x = x + tapir.linear(o.reshape(B, 1, _RH * _RHD), p["wo"])
    xn2 = L.rmsnorm(x, p["ln2"])
    return x + tapir.gated_mlp(xn2, p["wg"], p["wu"], p["wd"]), ck, cv


def _decode_init(key, n_blocks):
    params = _region_block_params(key, n_blocks)
    x = jax.random.normal(jax.random.fold_in(key, 98), (_RB, 1, _RD))
    caches = [(jnp.zeros((_RB, _DMAX, _RHKV, _RHD), jnp.float32),
               jnp.zeros((_RB, _DMAX, _RHKV, _RHD), jnp.float32))
              for _ in range(n_blocks)]
    return params, x, caches


def _decode_run(params, x, caches, steps, regions, blk):
    outs = []
    for t in range(steps):
        pos = jnp.asarray(t, jnp.int32)
        cos, sin = L.rope_table(jnp.arange(t, t + 1), _RHD)
        h = x
        for i, p in enumerate(params):
            ck, cv = caches[i]
            if regions:
                h, ck, cv = blk(p, h, ck, cv, pos, cos, sin)
            else:
                h, ck, cv = _decode_block(p, h, ck, cv, pos, cos, sin)
            caches[i] = (ck, cv)
        outs.append(h)
        x = jnp.tanh(h)   # feed back so steps depend on each other
    return x, caches, outs


def bench_decode_region_vs_per_op(iters: int = 3, steps: int = 16,
                                  n_blocks: int = 2,
                                  json_path="BENCH_decode.json"):
    """Times ``steps`` decode steps on an ``n_blocks`` transformer, per-op
    graphs vs one stateful region per block (library-call usage, no outer
    jit) — the dispatch-dominated serving regime.  Checks that the region
    path (a) bitwise-matches the per-op reference and (b) donates the
    cache buffers (storage reuse across steps, no per-step copy)."""
    key = jax.random.PRNGKey(3)
    blk = tapir.parallel_region(_decode_block, name="bench_decode_block")

    # correctness: bitwise match + donation, before timing
    params, x0, caches = _decode_init(key, n_blocks)
    with use(TapirConfig(mode="tapir", regions=False)):
        ref_x, ref_caches, _ = _decode_run(params, x0, list(caches), 4,
                                           False, blk)
    params, x0, caches = _decode_init(key, n_blocks)
    with use(TapirConfig(mode="tapir", regions=True)):
        ptr0 = caches[0][0].unsafe_buffer_pointer()
        got_x, got_caches, _ = _decode_run(params, x0, list(caches), 4,
                                           True, blk)
        donated = got_caches[0][0].unsafe_buffer_pointer() == ptr0
    bitwise = bool(np.array_equal(np.asarray(ref_x), np.asarray(got_x))) \
        and all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
                for (a, _), (b, _) in zip(ref_caches, got_caches))
    print(f"decode_region_vs_per_op bitwise={bitwise} donated={donated}")

    results = {}
    for label, regions in (("per_op", False), ("region", True)):
        clear_cache()
        with use(TapirConfig(mode="tapir", regions=regions)):
            params, x0, caches = _decode_init(key, n_blocks)
            _decode_run(params, x0, list(caches), 2, regions, blk)  # warm
            t0 = time.perf_counter()
            for _ in range(iters):
                params, x0, caches = _decode_init(key, n_blocks)
                out, _, _ = _decode_run(params, x0, list(caches), steps,
                                        regions, blk)
            jax.block_until_ready(out)
            t = (time.perf_counter() - t0) / (iters * steps)
            results[label] = {"ms_per_step": t * 1e3,
                              "cache": cache_stats()}
        print(f"decode_region_vs_per_op {label:8s} {t*1e3:9.3f} ms/step")
    speedup = (results["per_op"]["ms_per_step"]
               / results["region"]["ms_per_step"])
    print(f"decode_region_vs_per_op speedup: {speedup:.2f}x")
    out = {"per_op": results["per_op"], "region": results["region"],
           "speedup": speedup, "bitwise_match": bitwise, "donated": donated,
           "config": {"blocks": n_blocks, "B": _RB, "d": _RD,
                      "max_len": _DMAX, "steps": steps}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# serve_continuous_vs_wave: slot-paged continuous batching (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------


def bench_serve_continuous_vs_wave(iters: int = 3, slots: int = 4,
                                   json_path="BENCH_serve.json"):
    """Tokens/sec on MIXED-length requests: continuous slot scheduling
    (admit into free slots mid-decode, free on finish) vs wave scheduling
    (admit a full batch, block until its slowest member drains).  Both run
    the SAME slot primitives — one region program per block replayed from
    ``_PROGRAMS`` at every occupancy — so the outputs are bitwise-identical
    per request and the speedup isolates scheduler utilization."""
    import dataclasses

    import repro.configs as C
    from repro.models.base import get_model
    from repro.serve import Request, ServeConfig, ServingEngine

    cfg = dataclasses.replace(C.get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    plens = [6, 4, 7, 5, 6, 3, 7, 4, 6, 5, 4, 7]
    news = [4, 60, 6, 40, 8, 56, 4, 28, 6, 64, 12, 44]   # heavy mix
    prompts = [rng.integers(1, 100, size=n).astype(np.int32) for n in plens]

    def mk():
        return [Request(rid=i, prompt=p.copy(), max_new=m)
                for i, (p, m) in enumerate(zip(prompts, news))]

    clear_cache()
    eng = ServingEngine(model, params, batch=slots, max_len=128,
                        cfg=ServeConfig(target="cpu"))
    # warmup compiles every program (prefill buckets, decode, heads);
    # both schedulers replay the same cache afterwards
    ref = eng.run(mk(), max_steps=4096)
    eng.run_wave(mk(), max_steps=4096)

    # donation: the slot pages must update IN PLACE across decode steps
    # (scatter donation through the program-replay path)
    with use(ServeConfig(target="cpu").tapir_config()):
        sp = model.slot_params(params)
        cache = model.init_slot_cache(slots, 128)
        _, cache = model.prefill_into_slot(
            sp, jnp.zeros((1, 8), jnp.int32), cache, 0, 6)
        ptrs = [c.unsafe_buffer_pointer() for c in cache["k"]]
        step_toks = jnp.zeros((slots, 1), jnp.int32)
        for _ in range(2):
            _, cache = model.decode_step_slots(sp, step_toks, cache)
        donated = [c.unsafe_buffer_pointer()
                   for c in cache["k"]] == ptrs
    print(f"serve_continuous_vs_wave slot pages donated: {donated}")

    results = {}
    for label, runner in (("wave", eng.run_wave), ("continuous", eng.run)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = runner(mk(), max_steps=4096)
        t = (time.perf_counter() - t0) / iters
        toks = sum(len(r.out) for r in out)
        results[label] = {"wall_s": t, "tokens": toks,
                          "tok_per_s": toks / t}
        print(f"serve_continuous_vs_wave {label:10s} {t*1e3:9.1f} ms "
              f"({toks} tokens, {toks/t:8.1f} tok/s)")
        bitwise = all(a.out == b.out and a.done and b.done
                      for a, b in zip(ref, out))
        results[label]["bitwise_match"] = bitwise
    speedup = (results["continuous"]["tok_per_s"]
               / results["wave"]["tok_per_s"])
    bitwise = bool(results["wave"]["bitwise_match"]
                   and results["continuous"]["bitwise_match"])
    print(f"serve_continuous_vs_wave speedup: {speedup:.2f}x "
          f"(bitwise={bitwise})")
    out = {"wave": results["wave"], "continuous": results["continuous"],
           "speedup": speedup, "bitwise_match": bitwise,
           "donated": bool(donated),
           "config": {"slots": slots, "requests": len(news),
                      "max_new": news, "prompt_lens": plens}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# serve_prefix_vs_baseline: ref-counted shared prefix pages (ISSUE 9)
# ---------------------------------------------------------------------------


def bench_serve_prefix_vs_baseline(iters: int = 2, slots: int = 4,
                                   n_requests: int = 12,
                                   prefix_len: int = 512,
                                   json_path="BENCH_prefix.json"):
    """Tokens/sec on a system-prompt-heavy workload: ``n_requests``
    prompts sharing a ``prefix_len``-token prefix (distinct 8-token
    suffixes), served with the shared-prefix page index ON vs OFF.  With
    sharing, the first admit prefills the whole prompt and publishes the
    prefix pages; every later admit binds them read-only and prefills
    ONLY its suffix — prefill cost stops scaling with N.  Page
    indirection is data (per-slot page table), so the decode program
    replays from ``_PROGRAMS`` at every binding and the outputs stay
    bitwise-identical to the unshared engine."""
    import dataclasses

    import repro.configs as C
    from repro.models.base import get_model
    from repro.serve import Request, ServeConfig, ServingEngine

    cfg = dataclasses.replace(C.get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 400, size=prefix_len).astype(np.int32)
    suffixes = [rng.integers(1, 400, size=8).astype(np.int32)
                for _ in range(n_requests)]
    max_len = prefix_len + 64                      # 64 | max_len
    max_new = 8

    def mk():
        return [Request(rid=i,
                        prompt=np.concatenate([prefix, sfx]),
                        max_new=max_new)
                for i, sfx in enumerate(suffixes)]

    clear_cache()
    shared = ServingEngine(model, params, batch=slots, max_len=max_len,
                           cfg=ServeConfig(target="cpu"))
    base = ServingEngine(model, params, batch=slots, max_len=max_len,
                         cfg=ServeConfig(target="cpu",
                                         prefix_sharing=False))
    # warmup compiles every program (full-prompt bucket, suffix bucket,
    # decode, heads); the timed runs replay from ``_PROGRAMS``
    ref = base.run(mk(), max_steps=4096)
    shared.run(mk(), max_steps=4096)

    results = {}
    for label, eng in (("baseline", base), ("shared", shared)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = eng.run(mk(), max_steps=4096)
        t = (time.perf_counter() - t0) / iters
        toks = sum(len(r.out) for r in out)
        st = eng.last_stats
        results[label] = {
            "wall_s": t, "tokens": toks, "tok_per_s": toks / t,
            "bitwise_match": all(a.out == b.out and a.done and b.done
                                 for a, b in zip(ref, out)),
            "prefix_hits": st.get("prefix_hits", 0),
            "prefix_tokens_saved": st.get("prefix_tokens_saved", 0),
            # replay after warmup must not compile anything new: page
            # indirection is data, not shape
            "compiled_programs": st.get("compiled_programs", 0),
        }
        print(f"serve_prefix_vs_baseline {label:9s} {t*1e3:9.1f} ms "
              f"({toks} tokens, {toks/t:8.1f} tok/s, "
              f"hits={st.get('prefix_hits', 0)}, "
              f"saved={st.get('prefix_tokens_saved', 0)} tok)")
    speedup = (results["shared"]["tok_per_s"]
               / results["baseline"]["tok_per_s"])
    prefill_once = (results["shared"]["prefix_hits"] == n_requests - 1)
    bitwise = bool(results["baseline"]["bitwise_match"]
                   and results["shared"]["bitwise_match"])
    print(f"serve_prefix_vs_baseline speedup: {speedup:.2f}x "
          f"(bitwise={bitwise}, prefix prefilled once={prefill_once})")
    out = {"baseline": results["baseline"], "shared": results["shared"],
           "speedup": speedup, "bitwise_match": bitwise,
           "prefix_prefilled_once": bool(prefill_once),
           "warm_compiled": int(results["shared"]["compiled_programs"]),
           "config": {"slots": slots, "requests": n_requests,
                      "prefix_len": prefix_len, "suffix_len": 8,
                      "max_new": max_new, "max_len": max_len}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# serve_mesh_vs_single: slot serving on a TP mesh (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------


def bench_serve_mesh_vs_single(iters: int = 2, json_path="BENCH_mesh.json"):
    """Slot-paged serving on a forced-host-device ``(data, model)`` mesh
    vs the single-device slot engine: correctness-gated, not speed-gated
    (8 emulated host devices on one CPU pay SPMD overhead for zero real
    parallelism — the gate asserts the mesh run takes the SLOT path, its
    per-request outputs are bitwise-identical, and the region programs
    carry replayed sharding constraints).  Runs through the shared
    multi-device subprocess harness (``repro.testing`` — the same one
    the mesh tests use) because the device-count flag must be set
    before jax initializes."""
    from repro.testing import run_mesh_subprocess

    res = run_mesh_subprocess(f"""
        import time
        import repro.configs as C
        from repro.models.base import get_model
        from repro.serve import Request, ServeConfig, ServingEngine
        from repro.core.tapir import clear_cache, cached_graphs
        from repro.launch.mesh import make_test_mesh

        cfg = dataclasses.replace(C.get_smoke("qwen2_5_3b"),
                                  compute_dtype="float32")
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        lens = [6, 4, 7, 5, 6, 3, 7, 4]
        news = [4, 24, 6, 16, 8, 20, 4, 12]
        prompts = [rng.integers(1, 100, size=n).astype(np.int32)
                   for n in lens]

        def mk():
            return [Request(rid=i, prompt=p.copy(), max_new=m)
                    for i, (p, m) in enumerate(zip(prompts, news))]

        for label, mesh in (("single", None),
                            ("mesh", make_test_mesh(data=2, model=4))):
            clear_cache()
            eng = ServingEngine(model, params, mesh=mesh, batch=4,
                                max_len=64, cfg=ServeConfig(target="cpu"))
            res = eng.run(mk())        # warmup (compiles every program)
            t0 = time.perf_counter()
            for _ in range({iters}):
                res = eng.run(mk())
            wall = (time.perf_counter() - t0) / {iters}
            toks = sum(len(r.out) for r in res)
            result[label] = {{
                "wall_s": wall, "tokens": toks, "tok_per_s": toks / wall,
                "outs": [r.out for r in res],
                "slot_path": bool(eng._slot_capable),
                "stats": {{k: float(v) for k, v in eng.last_stats.items()}},
                "annotated_nodes": sum(
                    1 for g in cached_graphs().values()
                    for n in g.nodes.values() if n.sharding),
            }}
        result["bitwise_match"] = (
            result["single"]["outs"] == result["mesh"]["outs"])
    """, timeout=1200)
    for label in ("single", "mesh"):
        r = res[label]
        print(f"serve_mesh_vs_single {label:8s} {r['wall_s']*1e3:9.1f} ms "
              f"({r['tokens']} tokens, {r['tok_per_s']:8.1f} tok/s, "
              f"slot_path={r['slot_path']})")
    print(f"serve_mesh_vs_single bitwise={res['bitwise_match']} "
          f"mesh-annotated nodes={res['mesh']['annotated_nodes']}")
    out = {"single": {k: v for k, v in res["single"].items() if k != "outs"},
           "mesh": {k: v for k, v in res["mesh"].items() if k != "outs"},
           "bitwise_match": res["bitwise_match"],
           "slot_path_on_mesh": res["mesh"]["slot_path"],
           "mesh_annotated_nodes": res["mesh"]["annotated_nodes"],
           "config": {"mesh": "2x4 (data, model)", "slots": 4,
                      "requests": 8,
                      "max_new": [4, 24, 6, 16, 8, 20, 4, 12],
                      "prompt_lens": [6, 4, 7, 5, 6, 3, 7, 4]}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# serve_fault_vs_clean: recovery overhead under an injected failure (ISSUE 6)
# ---------------------------------------------------------------------------


def bench_serve_fault_vs_clean(iters: int = 3, slots: int = 4,
                               json_path="BENCH_fault.json"):
    """Recovery cost of the fault-tolerant serving loop: the standard
    mixed-length workload run clean vs with ONE injected decode-step crash
    (periodic slot checkpoints every 16 steps, crash at step 33 — one step
    past a checkpoint, so recovery is restore + short replay).  Greedy
    decode replayed from the restored slot state is deterministic, so the
    gate is twofold: per-request outputs bitwise-identical to the clean
    run, and wall-clock overhead (checkpoint saves + restore + replay)
    bounded."""
    import dataclasses
    import tempfile

    import repro.configs as C
    from repro.dist.fault import Fault, ScriptedFaultInjector
    from repro.models.base import get_model
    from repro.serve import Request, ServeConfig, ServingEngine

    cfg = dataclasses.replace(C.get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    plens = [6, 4, 7, 5, 6, 3, 7, 4, 6, 5, 4, 7]
    news = [4, 60, 6, 40, 8, 56, 4, 28, 6, 64, 12, 44]
    prompts = [rng.integers(1, 100, size=n).astype(np.int32) for n in plens]
    ckpt_every, crash_step = 16, 33

    def mk():
        return [Request(rid=i, prompt=p.copy(), max_new=m)
                for i, (p, m) in enumerate(zip(prompts, news))]

    def faulted_engine():
        # fresh one-shot injector + fresh checkpoint dir per run: the
        # crash fires exactly once every run, and no run restores a stale
        # checkpoint left by the previous one
        inj = ScriptedFaultInjector({crash_step: Fault("crash")})
        return ServingEngine(
            model, params, batch=slots, max_len=128,
            cfg=ServeConfig(target="cpu", fault_injector=inj,
                            ckpt_dir=tempfile.mkdtemp(),
                            ckpt_every=ckpt_every))

    clear_cache()
    eng = ServingEngine(model, params, batch=slots, max_len=128,
                        cfg=ServeConfig(target="cpu"))
    ref = eng.run(mk(), max_steps=4096)      # warmup compiles every program
    faulted_engine().run(mk(), max_steps=4096)   # warm the recovery path

    results = {}
    t0 = time.perf_counter()
    for _ in range(iters):
        out = eng.run(mk(), max_steps=4096)
    t = (time.perf_counter() - t0) / iters
    toks = sum(len(r.out) for r in out)
    results["clean"] = {"wall_s": t, "tokens": toks, "tok_per_s": toks / t,
                        "bitwise_match": True}

    bitwise, fstats = True, {}
    t_sum = 0.0
    for _ in range(iters):
        feng = faulted_engine()
        t0 = time.perf_counter()
        out = feng.run(mk(), max_steps=4096)
        t_sum += time.perf_counter() - t0
        bitwise = bitwise and all(a.out == b.out and a.done and b.done
                                  for a, b in zip(ref, out))
        fstats = {k: int(feng.last_stats[k]) for k in
                  ("failures", "restores", "checkpoints")}
    t = t_sum / iters
    toks = sum(len(r.out) for r in out)
    results["faulted"] = {"wall_s": t, "tokens": toks,
                          "tok_per_s": toks / t, "bitwise_match": bitwise}
    overhead = results["faulted"]["wall_s"] / results["clean"]["wall_s"] - 1.0
    for label in ("clean", "faulted"):
        r = results[label]
        print(f"serve_fault_vs_clean {label:8s} {r['wall_s']*1e3:9.1f} ms "
              f"({r['tokens']} tokens, {r['tok_per_s']:8.1f} tok/s)")
    print(f"serve_fault_vs_clean recovery overhead: {overhead*100:.1f}% "
          f"(bitwise={bitwise}, {fstats})")
    out = {"clean": results["clean"], "faulted": results["faulted"],
           "overhead": overhead, "bitwise_match": bitwise,
           "fault_stats": fstats,
           "config": {"slots": slots, "requests": len(news),
                      "ckpt_every": ckpt_every, "crash_step": crash_step,
                      "max_new": news, "prompt_lens": plens}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# program_cache_cold_vs_warm: persistent L2 warm start (ISSUE 8)
# ---------------------------------------------------------------------------

def bench_program_cache_cold_vs_warm(json_path="BENCH_cache.json"):
    """Warm-start benefit of the on-disk program cache: the same smoke
    serving workload in two fresh processes sharing one cache dir.  The
    cold process compiles every XLA program and publishes it; the warm
    process must compile ZERO (``compiled_programs == 0``), reach its
    first token >= 5x faster (time-to-first-token is the restart-latency
    number a serving fleet cares about), and emit bitwise identical
    tokens."""
    import shutil
    import tempfile

    from repro.testing import run_mesh_subprocess

    cache_dir = tempfile.mkdtemp(prefix="bench_l2_")
    body = """
    import time
    import repro.configs as C
    from repro.models.base import get_model
    from repro.serve import Request, ServeConfig, ServingEngine
    cfg = dataclasses.replace(C.get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 100, size=n).astype(np.int32)
               for n in (6, 4, 7, 5)]
    eng = ServingEngine(model, params, batch=4, max_len=64,
                        cfg=ServeConfig(target="cpu",
                                        program_cache_dir={d!r}))
    # warmup request in the SMALLEST prefill bucket: primes everything a
    # program acquisition does not include (slot-param slicing, state
    # zeros, eager dispatch helpers, the pooled decode program) —
    # identical work cold and warm.  Its region compiles publish to L2.
    eng.run([Request(rid=99,
                     prompt=rng.integers(1, 100, size=3).astype(np.int32),
                     max_new=2)], max_steps=64)
    # time-to-first-token for a request in a NEW prefill bucket (len 20
    # -> bucket 32, never seen above): the only un-primed work is
    # acquiring that bucket's program — XLA compile cold, verified L2
    # load warm.  This is the latency spike a serving fleet sees whenever
    # a new shape bucket arrives after a restart.
    t0 = time.perf_counter()
    eng.run([Request(rid=0,
                     prompt=rng.integers(1, 100, size=20).astype(np.int32),
                     max_new=1)], max_steps=64)
    ttft = time.perf_counter() - t0
    out = eng.run([Request(rid=i, prompt=p.copy(), max_new=8)
                   for i, p in enumerate(prompts)], max_steps=4096)
    import repro.core.tapir as tapir
    s = tapir.cache_stats()
    result.update(ttft_s=ttft,
                  outs=[list(map(int, r.out)) for r in out],
                  compiled=int(s["compiled_programs"]),
                  l2_hits=int(s["l2_hits"]), l2_writes=int(s["l2_writes"]),
                  l2_quarantined=int(s["l2_quarantined"]))
    """.format(d=cache_dir)
    try:
        cold = run_mesh_subprocess(body, devices=1)
        warm = run_mesh_subprocess(body, devices=1)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    speedup = cold["ttft_s"] / max(warm["ttft_s"], 1e-9)
    bitwise = cold["outs"] == warm["outs"]
    for label, r in (("cold", cold), ("warm", warm)):
        print(f"program_cache {label:5s} ttft {r['ttft_s']*1e3:9.1f} ms  "
              f"compiled={r['compiled']} l2_hits={r['l2_hits']} "
              f"l2_writes={r['l2_writes']}")
    print(f"program_cache warm-start ttft speedup: {speedup:.1f}x "
          f"(bitwise={bitwise})")
    out = {"cold": cold, "warm": warm, "ttft_speedup": speedup,
           "bitwise_match": bitwise,
           "warm_compiled": warm["compiled"],
           "quarantined": cold["l2_quarantined"] + warm["l2_quarantined"],
           "config": {"arch": "qwen2_5_3b smoke", "slots": 4,
                      "requests": 4, "max_new": 8}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# kernel_vs_jnp: does the impl registry pick the measured winner? (ISSUE 7)
# ---------------------------------------------------------------------------

#: (label, (B, Sq, Skv, Hq, Hkv, D, causal)) — one shape where the
#: blockwise online-softmax measurably beats the materialized score matrix
#: (decode against a long KV: score bytes + K/V repeat dominate) and one
#: where it loses (tiny prefill: per-block scan dispatch swamps a 16x16
#: score matrix).  The gate asserts the roofline argmin matches the
#: measured winner on BOTH — i.e. the cost model earns its keep at both
#: ends of the regime, not just where kernels shine.
_KVJ_SHAPES = (
    ("long_kv", (4, 1, 8192, 8, 2, 64, False)),
    ("short_seq", (2, 16, 16, 4, 4, 32, True)),
)


def bench_kernel_vs_jnp(iters: int = 30, json_path="BENCH_kernel.json"):
    """Measures every available attention candidate (forced via
    ``TapirConfig.force_impl``) against the impl registry's roofline
    choice on the two gate shapes.  Passes when ``schedule.impl`` names
    the measured-fastest impl on both."""
    out = {"shapes": {}}
    ok_all = True
    for label, (B, Sq, Skv, Hq, Hkv, D, causal) in _KVJ_SHAPES:
        key = jax.random.PRNGKey(11)
        q = jax.random.normal(key, (B, Sq, Hq, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1),
                              (B, Skv, Hkv, D), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2),
                              (B, Skv, Hkv, D), jnp.float32)

        # 1. what does the registry pick, and what did it estimate?
        clear_cache()
        with use(TapirConfig(mode="tapir", backend="cpu")):
            tapir.attention(q, k, v, causal=causal)
        node = next(n for g in tapir.cached_graphs().values()
                    for n in g.nodes.values() if n.op == "attention")
        model_impl, model_costs = node.schedule.impl, dict(node.schedule.impl_costs)

        # 2. measure each available candidate through the same jit path
        measured = {}
        for impl, cost in model_costs.items():
            if not isinstance(cost, float):
                continue
            cfg = TapirConfig(mode="tapir", backend="cpu",
                              force_impl=(("attention", impl),))
            clear_cache()

            @jax.jit
            def run(q, k, v):
                with use(cfg):
                    return tapir.attention(q, k, v, causal=causal)

            jax.block_until_ready(run(q, k, v))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(run(q, k, v))
                ts.append(time.perf_counter() - t0)
            measured[impl] = float(np.median(ts))

        winner = min(measured, key=measured.get)
        ok = model_impl == winner
        ok_all = ok_all and ok
        margin = max(measured.values()) / measured[winner]
        print(f"kernel_vs_jnp {label:10s} model={model_impl:20s} "
              f"measured_winner={winner:20s} "
              f"({', '.join(f'{i}={t*1e3:.2f}ms' for i, t in sorted(measured.items(), key=lambda kv: kv[1]))}) "
              f"{'OK' if ok else 'MISMATCH'}")
        out["shapes"][label] = {
            "shape": {"B": B, "Sq": Sq, "Skv": Skv, "Hq": Hq,
                      "Hkv": Hkv, "D": D, "causal": causal},
            "model_impl": model_impl,
            "model_costs": {i: (c if isinstance(c, float) else str(c))
                            for i, c in model_costs.items()},
            "measured_s": measured, "measured_winner": winner,
            "winner_margin": margin, "model_correct": ok,
        }
    out["model_correct"] = ok_all
    print(f"kernel_vs_jnp cost model picked the measured winner on "
          f"{'BOTH shapes' if ok_all else 'FEWER THAN BOTH shapes'}")
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


# ---------------------------------------------------------------------------
# train_region_vs_per_op: region-captured training step (ISSUE 10 tentpole)
# ---------------------------------------------------------------------------


def bench_train_region_vs_per_op(iters: int = 4, check_steps: int = 2,
                                 json_path="BENCH_train.json"):
    """One full training step (loss -> grads -> AdamW) on the qwen smoke
    model: per-op library-call usage (``regions=False``, no outer jit —
    ``jax.value_and_grad`` retraces and every op dispatches its own jit
    unit) vs the region-captured step (joint fwd+bwd task graph compiled
    once, replayed from the program cache with params + optimizer state
    donated).

    Correctness gates before timing: loss bitwise-equal across
    ``check_steps`` steps on a fixed seed, params + opt state bitwise at
    the end, and every param/mu/nu leaf updated IN PLACE on the replayed
    step (buffer-pointer identity).  Float32 compute: XLA CPU emulates
    bf16 by upcasting and re-rounds wherever fusion boundaries land, so
    bf16 bitwise across different jit partitionings is not well-defined
    (see tests/test_train_region.py)."""
    import dataclasses

    import repro.configs as Cfg
    from repro.models.base import get_model
    from repro.optim import AdamWConfig, adamw_update
    from repro.train import TrainConfig, init_state, make_region_train_step

    cfg = dataclasses.replace(Cfg.get_smoke("qwen2_5_3b"),
                              compute_dtype="float32")
    model = get_model(cfg)
    rng = np.random.default_rng(0)
    toks = [rng.integers(1, min(cfg.vocab, 100), size=(2, 16))
            for _ in range(max(check_steps, iters) + 1)]
    batches = [{"tokens": jnp.asarray(t, jnp.int32),
                "labels": jnp.asarray(t, jnp.int32)} for t in toks]
    opt_cfg = AdamWConfig(lr=3e-4, total_steps=64, warmup_steps=1)
    per_op_tap = dataclasses.replace(
        TrainConfig(mode="tapir", remat="full").tapir_config(),
        regions=False)

    def per_op_step(state, b):
        def loss_fn(p):
            with use(per_op_tap):
                return model.loss(p, b)
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        p2, o2, m = adamw_update(state["params"], grads, state["opt"],
                                 opt_cfg)
        return {"params": p2, "opt": o2}, {"loss": loss, **m}

    # correctness: bitwise losses + state, and in-place donation.  The
    # reference is the JITTED per-op step — eager per-op dispatch and a
    # single jit differ in the last f32 ulp on CPU (fusion moves where
    # elementwise chains round), so "bitwise" is always against the
    # canonical compiled reference, same as tests/test_train_region.py.
    clear_cache()
    ref_step = jax.jit(per_op_step)
    cap_step, _ = make_region_train_step(
        model, opt_cfg, mesh=None, cfg=TrainConfig(mode="tapir",
                                                   remat="auto"))
    ref = init_state(model, opt_cfg, jax.random.PRNGKey(0))
    cap = init_state(model, opt_cfg, jax.random.PRNGKey(0))
    bitwise = True
    for i in range(check_steps):
        ref, mr = ref_step(ref, batches[i])
        cap, mc = cap_step(cap, batches[i])
        bitwise &= bool(np.asarray(mr["loss"]).tobytes()
                        == np.asarray(mc["loss"]).tobytes())
    leaves = lambda s: jax.tree_util.tree_leaves(s["params"]) \
        + jax.tree_util.tree_leaves(s["opt"])                    # noqa: E731
    bitwise &= all(np.asarray(a).tobytes() == np.asarray(b).tobytes()
                   for a, b in zip(leaves(ref), leaves(cap)))
    ptr = lambda s: [l.unsafe_buffer_pointer() for l in leaves(s)]  # noqa: E731
    before = ptr(cap)
    cap, _ = cap_step(cap, batches[check_steps])     # replayed program
    donated = before == ptr(cap)
    print(f"train_region_vs_per_op bitwise={bitwise} donated={donated}")

    results = {}
    for label in ("per_op", "region"):
        clear_cache()
        state = init_state(model, opt_cfg, jax.random.PRNGKey(0))
        if label == "region":
            step, _ = make_region_train_step(
                model, opt_cfg, mesh=None,
                cfg=TrainConfig(mode="tapir", remat="auto"))
        else:
            step = per_op_step
        state, m = step(state, batches[0])           # warm: capture/compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(iters):
            state, m = step(state, batches[i + 1])
        jax.block_until_ready(m["loss"])
        t = (time.perf_counter() - t0) / iters
        results[label] = {"ms_per_step": t * 1e3, "cache": cache_stats()}
        print(f"train_region_vs_per_op {label:8s} {t*1e3:9.3f} ms/step")
    speedup = (results["per_op"]["ms_per_step"]
               / results["region"]["ms_per_step"])
    print(f"train_region_vs_per_op speedup: {speedup:.2f}x")
    out = {"per_op": results["per_op"], "region": results["region"],
           "speedup": speedup, "bitwise_match": bitwise, "donated": donated,
           "config": {"arch": "qwen2_5_3b-smoke", "B": 2, "S": 16,
                      "check_steps": check_steps, "iters": iters,
                      "compute_dtype": "float32"}}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {json_path}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("case", nargs="?", default="all",
                    choices=["all", "region_vs_per_op",
                             "decode_region_vs_per_op",
                             "serve_continuous_vs_wave",
                             "serve_prefix_vs_baseline",
                             "serve_mesh_vs_single",
                             "serve_fault_vs_clean",
                             "program_cache_cold_vs_warm",
                             "train_region_vs_per_op",
                             "kernel_vs_jnp"])
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    if args.case == "region_vs_per_op":
        bench_region_vs_per_op(iters=args.iters,
                               json_path=args.json or "BENCH_region.json")
        return
    if args.case == "decode_region_vs_per_op":
        bench_decode_region_vs_per_op(
            iters=args.iters, json_path=args.json or "BENCH_decode.json")
        return
    if args.case == "serve_continuous_vs_wave":
        bench_serve_continuous_vs_wave(
            iters=args.iters, json_path=args.json or "BENCH_serve.json")
        return
    if args.case == "serve_prefix_vs_baseline":
        bench_serve_prefix_vs_baseline(
            iters=args.iters, json_path=args.json or "BENCH_prefix.json")
        return
    if args.case == "serve_mesh_vs_single":
        bench_serve_mesh_vs_single(iters=args.iters,
                                   json_path=args.json or "BENCH_mesh.json")
        return
    if args.case == "serve_fault_vs_clean":
        bench_serve_fault_vs_clean(iters=args.iters,
                                   json_path=args.json or "BENCH_fault.json")
        return
    if args.case == "program_cache_cold_vs_warm":
        bench_program_cache_cold_vs_warm(
            json_path=args.json or "BENCH_cache.json")
        return
    if args.case == "train_region_vs_per_op":
        bench_train_region_vs_per_op(
            json_path=args.json or "BENCH_train.json")
        return
    if args.case == "kernel_vs_jnp":
        bench_kernel_vs_jnp(json_path=args.json or "BENCH_kernel.json")
        return

    key = jax.random.PRNGKey(0)
    out_rows, ratios = [], {}

    # 1. GEMM + bias + act + residual epilogue
    x = jax.random.normal(key, (512, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 1024))
    b = jax.random.normal(jax.random.fold_in(key, 2), (1024,))
    r, ratios["linear_epilogue"] = bench_op(
        "linear+bias+gelu", lambda x, w, b: tapir.linear(x, w, b, "gelu"),
        (x, w, b), args.iters)
    out_rows += r

    # 2. QKV shared-input fusion
    ws = [jax.random.normal(jax.random.fold_in(key, i), (512, 512))
          for i in (3, 4, 5)]
    r, ratios["qkv_fusion"] = bench_op(
        "qkv (3 proj, 1 input)", lambda x, *ws: tapir.multi_linear(x, ws),
        (x, *ws), args.iters)
    out_rows += r

    # 3. gated MLP (2 shared-input GEMMs + mul + down-proj)
    wg = jax.random.normal(jax.random.fold_in(key, 6), (512, 1024))
    wu = jax.random.normal(jax.random.fold_in(key, 7), (512, 1024))
    wd = jax.random.normal(jax.random.fold_in(key, 8), (1024, 512))
    r, ratios["gated_mlp"] = bench_op(
        "gated_mlp (swiglu)", lambda *t: tapir.gated_mlp(*t),
        (x, wg, wu, wd), args.iters)
    out_rows += r

    # 4. attention: materialized scores vs online-softmax composite
    q = jax.random.normal(key, (4, 1024, 8, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 9), (4, 1024, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 10), (4, 1024, 2, 64))
    r, ratios["attention"] = bench_op(
        "attention (GQA causal)",
        lambda q, k, v: tapir.attention(q, k, v, causal=True),
        (q, kk, v), args.iters, n_act=3)
    out_rows += r

    # 5. LSTM cell: 8 GEMMs -> 1
    xs = jax.random.normal(key, (64, 128))
    h = jax.random.normal(jax.random.fold_in(key, 11), (64, 256))
    c = jnp.zeros((64, 256))
    W = jax.random.normal(jax.random.fold_in(key, 12), (384, 1024)) * 0.05
    bb = jnp.zeros((1024,))
    r, ratios["lstm_cell"] = bench_op(
        "lstm_step (8->1 GEMM)", lambda *t: tapir.lstm_step(*t),
        (xs, h, c, W, bb), args.iters, n_act=3)
    out_rows += r

    # 6. wkv scan: sequential ref vs chunk-parallel
    S = 512
    q4 = jax.random.normal(key, (2, S, 4, 32))
    k4 = jax.random.normal(jax.random.fold_in(key, 13), (2, S, 4, 32))
    v4 = jax.random.normal(jax.random.fold_in(key, 14), (2, S, 4, 32))
    w4 = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(key, 15),
                                            (2, S, 4, 32)) * 0.3))
    u4 = jnp.zeros((4, 32))
    r, ratios["wkv_scan"] = bench_op(
        "wkv_scan (rwkv6)", lambda *t: tapir.wkv_scan(*t),
        (q4, k4, v4, w4, u4), args.iters, n_act=4)
    out_rows += r

    geo = float(np.exp(np.mean(np.log(list(ratios.values())))))
    print(f"{'geomean':24s} {'':30s} ratio={geo:5.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": out_rows, "ratios": ratios, "geomean": geo},
                      f, indent=1)


if __name__ == "__main__":
    main()
