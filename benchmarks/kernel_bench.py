"""Exposed-library-kernel benefit (paper §III "Exposing parallel
linear-algebra routines"): per-op wall time, opaque (sealed library call,
epilogue outside) vs tapir (exposed kernel, epilogue fused), on this CPU.

Also times each Pallas kernel in interpret mode vs its jnp oracle for a
correctness-perf sanity line (interpret mode is NOT a TPU perf proxy; the
TPU-side perf evidence is the dry-run roofline — see benchmarks/roofline).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tapir
from repro.core.tapir import TapirConfig, clear_cache, use


def _t(fn, *a, iters=10):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


INNER = 8   # op applications per timed call (see bench_op docstring)


def bench_op(name, fn, args, iters=10, n_act=1):
    """Times the op *in context*: a ``lax.scan`` of INNER steps in which
    the first ``n_act`` args (the activations) carry a per-iteration
    dependency while the remaining args (the weights) are loop-invariant —
    the way library ops appear in real networks (a time/layer loop).
    Fairness cuts both ways: weight-side fusion setup (concat/stack) is
    hoistable in both modes, and no mode may "win" by hoisting an
    activation projection that a real network recomputes every step.
    (The paper's §III point is exactly that calling context determines
    what the compiler can optimize.)"""
    rows = []
    for mode in ("opaque", "tapir"):
        clear_cache()
        cfg = TapirConfig(mode=mode)

        @jax.jit
        def run(*a):
            with use(cfg):
                acts, weights = a[:n_act], a[n_act:]

                def body(eps, _):
                    # nonlinear full-tensor perturbation: a scalar (or
                    # even multiplicative) carry commutes with linear ops
                    # and XLA hoists the whole GEMM out of the loop; tanh
                    # doesn't distribute, so each iteration really runs
                    cur = tuple(jnp.tanh(x + eps.astype(x.dtype))
                                for x in acts)
                    out = fn(*cur, *weights)
                    outs = out if isinstance(out, (tuple, list)) else (out,)
                    # consume EVERY output: otherwise DCE removes the
                    # unfused ops the fused form still has to compute
                    lead = sum(o.reshape(-1)[0] + o.reshape(-1)[-1]
                               for o in outs)
                    return 1e-30 * lead, lead

                _, ys = jax.lax.scan(body, jnp.zeros((), acts[0].dtype),
                                     None, length=INNER)
                return ys

        t = _t(run, *args, iters=iters) / INNER
        rows.append({"op": name, "mode": mode, "t_s": t})
    ratio = rows[0]["t_s"] / rows[1]["t_s"]
    print(f"{name:24s} opaque={rows[0]['t_s']*1e3:9.3f}ms "
          f"tapir={rows[1]['t_s']*1e3:9.3f}ms ratio={ratio:5.2f}")
    return rows, ratio


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    out_rows, ratios = [], {}

    # 1. GEMM + bias + act + residual epilogue
    x = jax.random.normal(key, (512, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 1024))
    b = jax.random.normal(jax.random.fold_in(key, 2), (1024,))
    r, ratios["linear_epilogue"] = bench_op(
        "linear+bias+gelu", lambda x, w, b: tapir.linear(x, w, b, "gelu"),
        (x, w, b), args.iters)
    out_rows += r

    # 2. QKV shared-input fusion
    ws = [jax.random.normal(jax.random.fold_in(key, i), (512, 512))
          for i in (3, 4, 5)]
    r, ratios["qkv_fusion"] = bench_op(
        "qkv (3 proj, 1 input)", lambda x, *ws: tapir.multi_linear(x, ws),
        (x, *ws), args.iters)
    out_rows += r

    # 3. gated MLP (2 shared-input GEMMs + mul + down-proj)
    wg = jax.random.normal(jax.random.fold_in(key, 6), (512, 1024))
    wu = jax.random.normal(jax.random.fold_in(key, 7), (512, 1024))
    wd = jax.random.normal(jax.random.fold_in(key, 8), (1024, 512))
    r, ratios["gated_mlp"] = bench_op(
        "gated_mlp (swiglu)", lambda *t: tapir.gated_mlp(*t),
        (x, wg, wu, wd), args.iters)
    out_rows += r

    # 4. attention: materialized scores vs online-softmax composite
    q = jax.random.normal(key, (4, 1024, 8, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 9), (4, 1024, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 10), (4, 1024, 2, 64))
    r, ratios["attention"] = bench_op(
        "attention (GQA causal)",
        lambda q, k, v: tapir.attention(q, k, v, causal=True),
        (q, kk, v), args.iters, n_act=3)
    out_rows += r

    # 5. LSTM cell: 8 GEMMs -> 1
    xs = jax.random.normal(key, (64, 128))
    h = jax.random.normal(jax.random.fold_in(key, 11), (64, 256))
    c = jnp.zeros((64, 256))
    W = jax.random.normal(jax.random.fold_in(key, 12), (384, 1024)) * 0.05
    bb = jnp.zeros((1024,))
    r, ratios["lstm_cell"] = bench_op(
        "lstm_step (8->1 GEMM)", lambda *t: tapir.lstm_step(*t),
        (xs, h, c, W, bb), args.iters, n_act=3)
    out_rows += r

    # 6. wkv scan: sequential ref vs chunk-parallel
    S = 512
    q4 = jax.random.normal(key, (2, S, 4, 32))
    k4 = jax.random.normal(jax.random.fold_in(key, 13), (2, S, 4, 32))
    v4 = jax.random.normal(jax.random.fold_in(key, 14), (2, S, 4, 32))
    w4 = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(key, 15),
                                            (2, S, 4, 32)) * 0.3))
    u4 = jnp.zeros((4, 32))
    r, ratios["wkv_scan"] = bench_op(
        "wkv_scan (rwkv6)", lambda *t: tapir.wkv_scan(*t),
        (q4, k4, v4, w4, u4), args.iters, n_act=4)
    out_rows += r

    geo = float(np.exp(np.mean(np.log(list(ratios.values())))))
    print(f"{'geomean':24s} {'':30s} ratio={geo:5.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": out_rows, "ratios": ratios, "geomean": geo},
                      f, indent=1)


if __name__ == "__main__":
    main()
