"""Benchmark entry point — one section per paper table/figure.

  §1 Fig. 3 reproduction      (the paper's only perf table: 4 networks,
                               opaque vs tapir wall-time on this CPU)
  §2 Exposed-kernel benefit   (paper §III library-exposure claim, per-op)
  §3 Small-task serialization ablation (paper §III Tapir/LLVM opts)
  §4 Roofline summary         (from the multi-pod dry-run artifacts, if
                               results/dryrun exists)

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced iters
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np


def check(out_dir: str, min_region_speedup: float = 1.5,
          min_decode_speedup: float = 1.3,
          min_serve_speedup: float = 1.3,
          max_fault_overhead: float = 0.25,
          min_warm_ttft_speedup: float = 5.0,
          min_prefix_speedup: float = 1.5,
          min_train_speedup: float = 1.3) -> int:
    """Perf regression gate: run the two region benchmarks, the
    continuous-batching benchmark, the mesh-serving benchmark and the
    fault-recovery benchmark, and FAIL (non-zero exit) if
    region_vs_per_op drops below ``min_region_speedup``,
    decode_region_vs_per_op below ``min_decode_speedup``,
    serve_continuous_vs_wave below ``min_serve_speedup``, any of them
    loses bitwise-match / stops donating cache buffers, mesh slot
    serving stops matching the single-device engine bitwise
    (serve_mesh_vs_single is correctness-gated only — emulated host
    devices are not a perf proxy), or serve_fault_vs_clean loses
    bitwise per-request equality between the faulted and clean runs /
    its recovery overhead exceeds ``max_fault_overhead`` wall-clock
    with one injected failure, or kernel_vs_jnp's impl registry stops
    picking the measured-fastest attention impl on either gate shape
    (a long-KV decode where blockwise wins and a tiny prefill where the
    materialized score matrix wins), or program_cache_cold_vs_warm's
    warm process compiles any XLA program / reaches its first token
    slower than ``min_warm_ttft_speedup`` vs cold / stops matching the
    cold run bitwise / quarantines entries on a clean cycle, or
    serve_prefix_vs_baseline's shared-prefix engine drops below
    ``min_prefix_speedup`` tokens/sec vs the unshared engine on a
    system-prompt-heavy workload / prefills the shared prefix more than
    once / loses bitwise per-request equality / compiles any program
    after warmup (page indirection must stay data, not shape), or
    train_region_vs_per_op's captured training step drops below
    ``min_train_speedup`` over the per-op path / loses bitwise loss +
    state equality across its checked steps / stops updating params and
    optimizer moments in place (donated buffers)."""
    os.makedirs(out_dir, exist_ok=True)
    from benchmarks import kernel_bench
    rv = kernel_bench.bench_region_vs_per_op(
        iters=10, json_path=os.path.join(out_dir, "BENCH_region.json"))
    dv = kernel_bench.bench_decode_region_vs_per_op(
        json_path=os.path.join(out_dir, "BENCH_decode.json"))
    sv = kernel_bench.bench_serve_continuous_vs_wave(
        json_path=os.path.join(out_dir, "BENCH_serve.json"))
    mv = kernel_bench.bench_serve_mesh_vs_single(
        json_path=os.path.join(out_dir, "BENCH_mesh.json"))
    fv = kernel_bench.bench_serve_fault_vs_clean(
        json_path=os.path.join(out_dir, "BENCH_fault.json"))
    kv = kernel_bench.bench_kernel_vs_jnp(
        json_path=os.path.join(out_dir, "BENCH_kernel.json"))
    cv = kernel_bench.bench_program_cache_cold_vs_warm(
        json_path=os.path.join(out_dir, "BENCH_cache.json"))
    pv = kernel_bench.bench_serve_prefix_vs_baseline(
        json_path=os.path.join(out_dir, "BENCH_prefix.json"))
    tv = kernel_bench.bench_train_region_vs_per_op(
        json_path=os.path.join(out_dir, "BENCH_train.json"))
    failures = []
    if rv["speedup"] < min_region_speedup:
        failures.append(f"region_vs_per_op speedup {rv['speedup']:.2f}x "
                        f"< {min_region_speedup}x")
    if dv["speedup"] < min_decode_speedup:
        failures.append(f"decode_region_vs_per_op speedup "
                        f"{dv['speedup']:.2f}x < {min_decode_speedup}x")
    if not dv["bitwise_match"]:
        failures.append("decode region no longer bitwise-matches per-op")
    if not dv["donated"]:
        failures.append("decode cache buffers no longer donated")
    if sv["speedup"] < min_serve_speedup:
        failures.append(f"serve_continuous_vs_wave tokens/sec speedup "
                        f"{sv['speedup']:.2f}x < {min_serve_speedup}x")
    if not sv["bitwise_match"]:
        failures.append("continuous batching no longer bitwise-matches "
                        "wave scheduling per request")
    if not sv["donated"]:
        failures.append("slot cache pages no longer donated across "
                        "decode steps")
    if not mv["bitwise_match"]:
        failures.append("mesh slot serving no longer bitwise-matches the "
                        "single-device slot engine per request")
    if not mv["slot_path_on_mesh"]:
        failures.append("mesh serving fell back to padded waves (slot "
                        "path lost)")
    if not mv["mesh_annotated_nodes"]:
        failures.append("mesh slot programs carry no sharding annotations "
                        "(constraints dropped by the tracer again)")
    if not fv["bitwise_match"]:
        failures.append("faulted serving run no longer bitwise-matches the "
                        "clean run per request (recovery replay broke "
                        "determinism)")
    if fv["fault_stats"].get("failures") != 1 \
            or fv["fault_stats"].get("restores") != 1:
        failures.append(f"fault benchmark expected exactly 1 injected "
                        f"failure + 1 restore, got {fv['fault_stats']}")
    if fv["overhead"] >= max_fault_overhead:
        failures.append(f"fault recovery overhead {fv['overhead']*100:.1f}% "
                        f">= {max_fault_overhead*100:.0f}% wall-clock")
    for label, shp in kv["shapes"].items():
        if not shp["model_correct"]:
            failures.append(
                f"kernel_vs_jnp[{label}]: impl registry picked "
                f"{shp['model_impl']} but {shp['measured_winner']} measured "
                f"fastest")
    if cv["warm_compiled"] != 0:
        failures.append(f"program cache warm start compiled "
                        f"{cv['warm_compiled']} programs (must be 0 — the "
                        f"L2 store stopped hitting)")
    if cv["ttft_speedup"] < min_warm_ttft_speedup:
        failures.append(f"program cache warm-start ttft speedup "
                        f"{cv['ttft_speedup']:.1f}x "
                        f"< {min_warm_ttft_speedup}x")
    if not cv["bitwise_match"]:
        failures.append("warm-start serving no longer bitwise-matches the "
                        "cold run (replayed executable drifted)")
    if cv["quarantined"]:
        failures.append(f"program cache quarantined {cv['quarantined']} "
                        f"entries on a clean cold/warm cycle")
    if pv["speedup"] < min_prefix_speedup:
        failures.append(f"serve_prefix_vs_baseline tokens/sec speedup "
                        f"{pv['speedup']:.2f}x < {min_prefix_speedup}x")
    if not pv["bitwise_match"]:
        failures.append("shared-prefix serving no longer bitwise-matches "
                        "the unshared engine per request")
    if not pv["prefix_prefilled_once"]:
        failures.append(f"shared prefix was re-prefilled: expected "
                        f"{pv['config']['requests'] - 1} prefix hits, got "
                        f"{pv['shared']['prefix_hits']}")
    if pv["warm_compiled"] != 0:
        failures.append(f"prefix-sharing engine compiled "
                        f"{pv['warm_compiled']} programs after warmup "
                        f"(page indirection leaked into program identity)")
    if tv["speedup"] < min_train_speedup:
        failures.append(f"train_region_vs_per_op speedup "
                        f"{tv['speedup']:.2f}x < {min_train_speedup}x")
    if not tv["bitwise_match"]:
        failures.append("captured training step no longer bitwise-matches "
                        "the per-op step (loss/params/opt state)")
    if not tv["donated"]:
        failures.append("captured training step stopped updating params/"
                        "optimizer moments in place (donation lost)")
    if failures:
        print("CHECK FAILED:")
        for f in failures:
            print(" -", f)
        return 1
    print(f"CHECK OK: region {rv['speedup']:.2f}x, "
          f"decode {dv['speedup']:.2f}x, "
          f"serve {sv['speedup']:.2f}x, mesh bitwise "
          f"({mv['mesh_annotated_nodes']} sharded nodes), fault recovery "
          f"{fv['overhead']*100:+.1f}% bitwise, donated, kernel_vs_jnp "
          f"impl choice measured-correct on both shapes, warm start "
          f"{cv['ttft_speedup']:.1f}x ttft with 0 compiles bitwise, "
          f"prefix sharing {pv['speedup']:.2f}x bitwise with prefix "
          f"prefilled once, captured train step {tv['speedup']:.2f}x "
          f"bitwise + donated")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="CI gate: fail if region speedups regress")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    if args.check:
        sys.exit(check(args.out))
    os.makedirs(args.out, exist_ok=True)
    iters = 3 if args.quick else 5
    batch = 32 if args.quick else 64

    print("=" * 72)
    print("§1 Fig. 3 reproduction (opaque = stock-XLA lowering, "
          "tapir = this paper)")
    print("=" * 72)
    from benchmarks import fig3
    sys.argv = ["fig3", "--batch", str(batch), "--iters", str(iters),
                "--json", os.path.join(args.out, "fig3.json")]
    fig3.main()

    print()
    print("=" * 72)
    print("§2 Exposed-kernel fusion benefit (per library op)")
    print("=" * 72)
    from benchmarks import kernel_bench
    sys.argv = ["kernel_bench", "--iters", str(iters),
                "--json", os.path.join(args.out, "kernel_bench.json")]
    kernel_bench.main()

    print()
    print("=" * 72)
    print("§3 Small-task serialization ablation (tapir mode, "
          "serialization pass off)")
    print("=" * 72)
    sys.argv = ["fig3", "--batch", str(batch), "--iters", str(iters),
                "--ablate-serialization",
                "--json", os.path.join(args.out, "fig3_ablate.json")]
    fig3.main()
    try:
        with open(os.path.join(args.out, "fig3.json")) as f:
            base = json.load(f)["geomean_ratio"]
        with open(os.path.join(args.out, "fig3_ablate.json")) as f:
            abl = json.load(f)["geomean_ratio"]
        print(f"serialization contribution: geomean {base:.2f}x -> "
              f"{abl:.2f}x without the pass")
    except Exception:
        pass

    print()
    print("=" * 72)
    print("§4 Roofline summary (from multi-pod dry-run)")
    print("=" * 72)
    dr = os.path.join("results", "dryrun_final")
    if not os.path.isdir(dr):
        dr = os.path.join("results", "dryrun")
    if os.path.isdir(dr):
        from benchmarks import roofline
        rows = roofline.load(dr)
        print(roofline.fmt(rows))
    else:
        print("results/dryrun not found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--mesh both` (CPU-only; ~1-2h)")


if __name__ == "__main__":
    main()
