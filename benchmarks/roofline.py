"""Aggregate dry-run JSONs into the roofline table (EXPERIMENTS.md
§Roofline source of truth).

    PYTHONPATH=src python -m benchmarks.roofline --dir results/dryrun
    PYTHONPATH=src python -m benchmarks.roofline --dir results/dryrun --md
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str, tag: str | None = None) -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        base = os.path.basename(fn)[:-5]
        if tag is not None and not base.endswith(f"_{tag}"):
            continue
        if tag is None and any(base.endswith(f"_{t}") for t in
                               ("opaque", "sp", "mb16", "tuned")):
            # default view: baseline cells only
            pass
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt(rows: list[dict], md: bool = False) -> str:
    hdr = ["arch", "shape", "mesh", "status", "t_comp(s)", "t_mem(s)",
           "t_coll(s)", "bound", "MF/HLO", "roofline%"]
    lines = []
    sep = " | " if md else "  "
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "|".join("---" for _ in hdr) + "|")
    else:
        lines.append(sep.join(f"{h:>12s}" if i > 2 else f"{h:22s}"
                              for i, h in enumerate(hdr)))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("mesh", ""))):
        if r["status"] != "ok":
            cells = [r["arch"], r["shape"], r.get("mesh", ""), r["status"],
                     "-", "-", "-", "-", "-", "-"]
        else:
            cells = [
                r["arch"], r["shape"], r["mesh"], "ok",
                f"{r['t_compute_s']:.4f}", f"{r['t_memory_s']:.4f}",
                f"{r['t_collective_s']:.4f}", r["bottleneck"],
                f"{r.get('useful_flops_ratio', 0):.2f}",
                f"{100 * r.get('roofline_fraction', 0):.2f}%",
            ]
        if md:
            lines.append("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            lines.append(sep.join(
                f"{str(c):>12s}" if i > 2 else f"{str(c):22s}"
                for i, c in enumerate(cells)))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_final")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.tag)
    if not rows:
        print(f"no dry-run results in {args.dir} — run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return
    print(fmt(rows, md=args.md))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r.get("roofline_fraction", 0))
        coll = max(ok, key=lambda r: r.get("t_collective_s", 0))
        print(f"\ncells ok={len(ok)} skip="
              f"{sum(1 for r in rows if r['status'] == 'skip')} fail="
              f"{sum(1 for r in rows if r['status'] == 'fail')}")
        print(f"worst roofline: {worst['arch']}/{worst['shape']}/"
              f"{worst['mesh']} ({100*worst['roofline_fraction']:.2f}%)")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']}/"
              f"{coll['mesh']} (t_coll={coll['t_collective_s']:.2f}s)")


if __name__ == "__main__":
    main()
